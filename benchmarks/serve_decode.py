"""Decode-time serving throughput: host-loop vs device-resident DecodeServer.

Measures decode tokens/sec (B x decode-steps per wall second) of the
per-token host-loop baseline (``runtime.serve_loop.HostLoopDecoder``:
per-step exit-mask sync, Python walk over hard tokens, per-sample bucket
re-stacking of hidden rows AND stage-2 KV-cache rows, per-sample cache
scatter-back) against the device-resident ``DecodeServer`` (fused exit
decision + compaction through ``kernels.dispatch``, hidden + cache-segment
rows through the pytree ring, bucketed async stage-2 dispatch, on-device
cache scatter) across per-token hard rates q ∈ {0.1, 0.3, 0.5}. C_thr is
calibrated per q on the first decode step's exit-head confidences, and the
stage-2 bucket is sized at ceil(q·B) — the paper's matched p=q operating
point applied per token.

Both servers share the same jitted stage callables (one ``DecodeFns``), so
the delta is purely the exit machinery, and merged per-token logits are
verified bitwise identical before timing. Run via
``PYTHONPATH=src python -m benchmarks.run --only serve_decode [--json]``.

When >= 2 devices are visible (CI pins 8 host devices), each q also runs
the STAGE-DISAGGREGATED ``DecodeServer`` — stage 1 on one submesh, the
ring + stage-2 cache store + bucketed dispatches on the other, chips
apportioned q-proportionally unless ``--chips1/--chips2`` override — and
enforces bitwise token/logits parity against the single-device server
before timing; per-stage device counts + occupancy ride in the ``--json``
envelope.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import table
from benchmarks.serve_pipeline import make_disagg_placement
from repro.core import early_exit as ee
from repro.models.config import ArchConfig
from repro.runtime import serve_loop as SL

Q_GRID = (0.1, 0.3, 0.5)


def _bench_cfg() -> ArchConfig:
    """Small enough that the per-token exit machinery (the thing under
    test) is a visible share of the step period on CPU; the model compute
    itself is identical between the two servers."""
    return ArchConfig(
        name="serve-decode-bench", family="dense", n_layers=4, d_model=32,
        n_heads=4, n_kv_heads=2, d_ff=64, vocab=256,
        dtype="float32", param_dtype="float32", tie_embeddings=True,
    )


def _time_decode(make_server, prompt, n_tokens: int, iters: int) -> tuple:
    """Best-of-iters wall time over one generate stream (fresh server per
    iteration; the jitted stage fns are shared, so no recompilation)."""
    make_server().generate(prompt[:2], max(2, n_tokens // 2))  # warmup
    best, stats = float("inf"), None
    for _ in range(iters):
        server = make_server()
        t0 = time.perf_counter()
        out = server.generate(prompt, n_tokens)
        best = min(best, time.perf_counter() - t0)
        stats = server.stats
        assert out["tokens"].shape == (prompt.shape[0], n_tokens)
    tps = prompt.shape[0] * (n_tokens - 1) / best      # decode steps / s
    return tps, stats


def run(fast: bool = False, chips1: Optional[int] = None,
        chips2: Optional[int] = None) -> dict:
    batch, seq = 64, 8
    n_tokens = 8 if fast else 16
    iters = 2 if fast else 3
    cfg = _bench_cfg()
    spec0 = ee.EarlyExitSpec(exit_layer=2, c_thr=0.5)
    params = ee.init_ee_params(jax.random.PRNGKey(0), cfg, spec0)
    prompt = np.asarray(jax.random.randint(jax.random.PRNGKey(1),
                                           (batch, seq), 0, cfg.vocab))
    conf = SL.decode_step0_confidences(params, cfg, spec0, prompt,
                                       max_len=seq + n_tokens)
    fns = SL.decode_stage_fns(params, cfg, spec0)  # c_thr never baked in

    n_dev = jax.device_count()
    rows, data = [], {}
    all_parity = True
    for q in Q_GRID:
        # C_thr at the q-quantile of confidence => a q token fraction hard
        c_thr = float(jnp.quantile(conf, q))
        capacity = max(4, int(np.ceil(q * batch)))
        sc = SL.ServeConfig(capacity=capacity, queue_depth=4, c_thr=c_thr)

        # bitwise parity gate before timing: same logits, same tokens
        od = SL.DecodeServer(fns, sc).generate(prompt, max(3, n_tokens // 4))
        oh = SL.HostLoopDecoder(fns, sc).generate(prompt,
                                                  max(3, n_tokens // 4))
        parity = (np.array_equal(od["logits"], oh["logits"])
                  and np.array_equal(od["tokens"], oh["tokens"]))
        assert parity, f"decode parity broke at q={q}"

        # disaggregated parity gate BEFORE timing (>= 2 devices): submesh
        # DecodeServer vs the single-device one, bit for bit
        placement = make_disagg_placement(q, chips1, chips2)
        c1 = placement.ex1.n_devices if placement else 1
        c2 = placement.ex2.n_devices if placement else 1
        occ = {}
        dis_parity = True
        if placement is not None:
            spec = ee.EarlyExitSpec(exit_layer=spec0.exit_layer, c_thr=c_thr)
            dis = SL.build_decode_server(params, cfg, spec, sc, placement)
            odis = dis.generate(prompt, max(3, n_tokens // 4))
            dis_parity = (np.array_equal(odis["logits"], od["logits"])
                          and np.array_equal(odis["tokens"], od["tokens"]))
            assert dis_parity, f"disaggregated decode parity broke at q={q}"
            occ = {"stage1_occupancy": dis.stats.stage1_occupancy,
                   "stage2_occupancy": dis.stats.stage2_occupancy}
        all_parity &= dis_parity

        host_tps, host_stats = _time_decode(
            lambda: SL.HostLoopDecoder(fns, sc), prompt, n_tokens, iters)
        dev_tps, dev_stats = _time_decode(
            lambda: SL.DecodeServer(fns, sc), prompt, n_tokens, iters)
        speedup = dev_tps / host_tps
        rows.append([f"{q:.1f}", f"{dev_stats.realized_q:.2f}", capacity,
                     f"{host_tps:,.0f}", f"{dev_tps:,.0f}",
                     f"{speedup:.2f}x",
                     f"{dev_stats.mean_bucket_fill:.2f}", parity,
                     f"{c1}+{c2}" if placement else "-"])
        data[f"q{q}"] = {"host_tps": host_tps, "device_tps": dev_tps,
                         "speedup": speedup, "parity": bool(parity),
                         "realized_q": dev_stats.realized_q,
                         "chips1": c1, "chips2": c2,
                         **occ}

    # vacuously true on a 1-device host; CI pins 8 host devices
    data["disagg"] = {"devices": n_dev, "checked": n_dev >= 2,
                      "parity": bool(all_parity)}
    txt = table(
        "Decode serving: host-loop vs device-resident "
        f"(B={batch}, prompt={seq}, T={n_tokens}, "
        f"backend={jax.default_backend()}, devices={n_dev})",
        ["q", "realized q", "bucket C", "host tok/s", "device tok/s",
         "speedup", "bucket fill", "bitwise", "submesh"], rows)
    return {"text": txt, **data}


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--chips1", type=int, default=None,
                    help="stage-1 submesh size (default: plan-derived)")
    ap.add_argument("--chips2", type=int, default=None,
                    help="stage-2 submesh size (default: plan-derived)")
    a = ap.parse_args()
    print(run(fast=a.fast, chips1=a.chips1, chips2=a.chips2)["text"])
