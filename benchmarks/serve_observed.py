"""Observability overhead gate: the continuous scheduler with the FULL
observability plane attached (request-span tracing + metrics sampling +
named scopes) vs the same scheduler running dark.

Observability that costs goodput gets turned off in production, at which
point the first incident is debugged blind — so the plane's contract is
that it is effectively free. The observed configuration here is the
everything-on worst case short of an active profiler capture:

  * a 65536-cap ``telemetry.EventLog`` wired into the scheduler (every
    submit/admit/park/bucket/finish/tick emits a dict);
  * an ``observe.Tracer`` subscribed to that feed, assembling per-request
    span trees synchronously inside ``emit``;
  * an ``observe.StatsSampler`` subscribed to the same feed, walking
    ``ServeStats`` into the metrics registry on its cadence;
  * the ``jax.named_scope`` / ``observe.annotate`` hooks in the tick and
    dispatch hot bodies (always compiled in; annotate is a shared
    nullcontext unless a ProfileWindow is active).

Gates (``benchmarks/compare.py`` against ``baseline_cpu.json``):

  * ``overhead_ratio`` = median of per-pair observed/dark goodput ratios,
    hard ``min`` 0.95 — the <= 5% overhead contract. The pair is the
    robust unit against runner drift (machine speed on shared boxes swings
    >10% over tens of seconds, and both sides of a back-to-back pair see
    the same state); alternating which side runs first inside each pair
    cancels the residual within-pair drift, and the median sheds
    stall-poisoned pairs. Same scheme as serve_continuous, with more
    pairs because this floor is far tighter than its 1.3x one;
  * ``equivalence`` — observed token streams bitwise-equal to both the
    unobserved scheduler and the ``HostLoopDecoder`` oracle (tracing must
    never perturb results);
  * ``span_complete`` — every submitted request assembles exactly one
    well-nested span tree (root covers queue-wait/decode/stage-2 children,
    no orphans, no still-open requests).

Run via ``PYTHONPATH=src python -m benchmarks.run --only serve_observed
[--json]``.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import table
from repro.core import early_exit as ee
from repro.models.config import ArchConfig
from repro.runtime import observe
from repro.runtime import serve_loop as SL
from repro.runtime.scheduler import Request, poisson_arrivals
from repro.runtime.telemetry import EventLog

ARRIVAL_RATE = 2000.0      # saturating (see serve_continuous)
Q = 0.3                    # the CI-gated operating point


def _bench_cfg() -> ArchConfig:
    """Wider than serve_continuous's bench model ON PURPOSE: that bench
    wants scheduling overhead visible against near-zero tick compute, but
    the observability contract is about a REAL serving load, where a tick
    costs model-forward time and the plane's per-event host work must
    amortize into it. d_model=32 would charge the plane against μs ticks
    and gate a regime no deployment runs in."""
    return ArchConfig(
        name="serve-obs-bench", family="dense", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=256, vocab=256,
        dtype="float32", param_dtype="float32", tie_embeddings=True,
    )


def _make_requests(prompts: np.ndarray, n_tokens: np.ndarray,
                   seed: int) -> List[Request]:
    arrivals = poisson_arrivals(len(prompts), ARRIVAL_RATE, seed)
    return [Request(sample_id=i, prompt=prompts[i], n_tokens=int(n_tokens[i]),
                    arrival_time=float(arrivals[i]))
            for i in range(len(prompts))]


def _observed_pass(fns, sc, n_slots, max_len, reqs):
    """One pass with the full plane attached; returns
    (goodput, results, tracer, registry). The plane is constructed BEFORE
    the scheduler — its clock starts at construction, so setup must not be
    billed to the makespan."""
    events = EventLog(cap=65536)
    tracer = observe.Tracer()
    registry = observe.MetricsRegistry()
    sampler = observe.StatsSampler(registry)
    sched = SL.ContinuousScheduler(fns, sc, n_slots=n_slots,
                                   max_len=max_len, events=events)
    tracer.attach_scheduler(sched)
    sampler.attach_scheduler(sched)
    for r in reqs:
        sched.submit(r)
    results = sched.run()
    goodput = sum(len(v) for v in results.values()) / sched.clock.now()
    sampler.sample()
    sampler.close()
    tracer.close()
    return goodput, results, tracer, registry


def _dark_pass(fns, sc, n_slots, max_len, reqs):
    sched = SL.ContinuousScheduler(fns, sc, n_slots=n_slots, max_len=max_len)
    for r in reqs:
        sched.submit(r)
    results = sched.run()
    return sum(len(v) for v in results.values()) / sched.clock.now(), results


def run(fast: bool = False) -> dict:
    # Longer passes than serve_continuous's: the gate is a hard 5%-overhead
    # floor, and per-pass noise (GC pauses, CPU steal on shared runners)
    # amortizes with makespan — a 20ms stall is 5% of a 0.4s pass but 1.5%
    # of a 1.3s one.
    seq = 8
    if fast:
        n_requests, n_slots, tok_choices = 96, 8, (6, 8, 12, 40)
    else:
        n_requests, n_slots, tok_choices = 144, 16, (6, 8, 12, 40)
    max_tok = max(tok_choices)
    max_len = seq + max_tok
    cfg = _bench_cfg()
    spec0 = ee.EarlyExitSpec(exit_layer=2, c_thr=0.5)
    params = ee.init_ee_params(jax.random.PRNGKey(0), cfg, spec0)
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (n_requests, seq), 0, cfg.vocab))
    n_tokens = np.random.default_rng(7).choice(tok_choices, size=n_requests)
    conf = SL.decode_step0_confidences(params, cfg, spec0, prompts[:n_slots],
                                       max_len=max_len)
    fns = SL.decode_stage_fns(params, cfg, spec0)
    c_thr = float(jnp.quantile(conf, Q))
    capacity = max(2, int(np.ceil(Q * n_slots)))
    sc = SL.ServeConfig(capacity=capacity, queue_depth=4, c_thr=c_thr)
    reqs = _make_requests(prompts, n_tokens, seed=11)
    expect_sids = set(range(n_requests))

    # --- correctness gates BEFORE timing: the observed run must change
    # nothing but emit everything
    oracle = SL.HostLoopDecoder(fns, sc).generate(prompts, max_tok)
    _, res_obs, tracer, registry = _observed_pass(
        fns, sc, n_slots, max_len, reqs)
    _, res_dark = _dark_pass(fns, sc, n_slots, max_len, reqs)
    equiv = all(
        res_obs[i] == res_dark[i]
        and [int(x) for x in oracle["tokens"][i][:int(n_tokens[i])]]
        == res_obs[i]
        for i in range(n_requests))
    assert equiv, "observed token streams diverged from dark/oracle"

    comp = tracer.completeness(expect_sids)
    assert comp["complete"], f"span trees incomplete: {comp}"

    # the sampler actually fed the registry, and the exposition both
    # renders and parses — the full export path, not just the counters
    parsed = observe.parse_exposition(registry.exposition())
    n_fin = parsed.get('repro_requests_finished_total{replica="0"}', 0.0)
    assert n_fin == float(n_requests), \
        f"metrics saw {n_fin} finished, expected {n_requests}"

    # --- timed alternating pairs (warmup already happened via the
    # equivalence passes above); median of per-pair ratios, see module doc
    iters = 10 if fast else 6
    obs_g, dark_g, ratios = [], [], []
    for i in range(iters):
        if i % 2 == 0:
            o = _observed_pass(fns, sc, n_slots, max_len, reqs)[0]
            d = _dark_pass(fns, sc, n_slots, max_len, reqs)[0]
        else:
            d = _dark_pass(fns, sc, n_slots, max_len, reqs)[0]
            o = _observed_pass(fns, sc, n_slots, max_len, reqs)[0]
        obs_g.append(o)
        dark_g.append(d)
        ratios.append(o / d)
    best_obs, best_dark = max(obs_g), max(dark_g)
    ratio = float(np.median(ratios))

    txt = table(
        "Observability overhead: full plane vs dark "
        f"(N={n_requests}, slots={n_slots}, q={Q}, "
        f"backend={jax.default_backend()})",
        ["dark tok/s", "observed tok/s", "obs/dark", "spans", "streams =="],
        [[f"{best_dark:,.0f}", f"{best_obs:,.0f}", f"{ratio:.3f}x",
          comp["n_spans"], equiv]])
    return {"text": txt,
            "overhead_ratio": ratio,
            "observed_goodput": best_obs,
            "unobserved_goodput": best_dark,
            "equivalence": bool(equiv),
            "span_complete": bool(comp["complete"]),
            "n_spans": comp["n_spans"],
            "n_span_annotations": comp["n_annotations"]}


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    a = ap.parse_args()
    print(run(fast=a.fast)["text"])
