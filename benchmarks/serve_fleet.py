"""Fleet routing under bimodal-difficulty tenant traffic: drift-aware
routing vs round-robin over provisioning-asymmetric replicas.

ATHEENA's principle — provision hardware to the exit probability p of the
traffic a section actually sees — extends to fleet routing: the router
should SHAPE per-replica traffic so each replica's provisioning stays
matched to its realized hard rate. This benchmark builds a 2-replica
fleet with deliberately asymmetric provisioning (an exit-heavy replica
whose stage-2 bucket is sized for p≈0.1, and a fat replica sized for
p≈0.85) and a bimodal tenant mix (an easy tenant whose requests nearly
always exit at stage 1, and a hard tenant whose requests nearly always
fall through). The workload rides ``serve_drift``'s analytic ``DecodeFns``
(deterministic confidences + real matmul burn), so misrouting has a real
wall cost: hard traffic on the small-bucket replica degenerates into
per-token bucket dispatches and ring backpressure stalls.

Two timed passes per iteration over the SAME trace (fresh fleet each):

  * **round_robin**  — the policy-blind baseline;
  * **drift_aware**  — the router learns each tenant's difficulty from the
    replicas' finish feeds and steers by |d̂ − p| plus the replica's
    realized-q saturation penalty.

An untimed correctness pass exercises the rest of the fleet contract:
per-sample token streams exactly equal to a single-scheduler oracle run
(and to the analytic stream), zero drops/dups under SLO preemption
(a mid-trace burst of gold-class traffic displaces queued batch-class
requests back into the router) and one forced mid-trace replica degrade
(queued requests revoked and redistributed; in-flight work drains).

Gated metrics (``benchmarks/compare.py`` vs ``baseline_cpu.json``):

  * ``drift_aware_vs_rr_goodput_ratio`` — median paired ratio, hard
    ``min`` 1.1;
  * ``fleet_equivalence`` / ``degrade_equivalence`` — exact-stream
    booleans;
  * ``preemption_exercised`` — the preemption path actually ran;
  * ``dropped_requests`` — hard-capped at 0 (re-queued, never dropped).

Run via ``PYTHONPATH=src python -m benchmarks.run --only serve_fleet
[--json]``.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

import jax

from benchmarks.common import table
from benchmarks.serve_drift import _S, drift_fns, token_of
from repro.runtime import serve_loop as SL
from repro.runtime.router import FleetRouter
from repro.runtime.scheduler import (Clock, ContinuousScheduler, Request,
                                     poisson_arrivals)

# the bimodal tenant mix: confidences sit at difficulty ± 0.09 jitter, so
# against C_THR the easy tenant's hard rate is ~0.11 and the hard
# tenant's ~0.89 — the two provisioning points the replicas are sized for
C_THR = 0.55
EASY_DIFF, HARD_DIFF = 0.62, 0.48
P_EXIT_HEAVY, P_FAT = 0.12, 0.85

# provisioning asymmetry, ATHEENA-style: stage-2 hardware scales with the
# provisioned p, so the exit-heavy replica's stage-2 is SLOW per row (few
# chips — emulated as more matmul burn) with a 1-row bucket, while the fat
# replica's stage-2 is fast per row with a full-width bucket. Misrouted
# hard traffic pays the slow stage 2 AND per-token dispatch overhead.
_D_MODEL = 256
_BURN2_EXIT_HEAVY, _BURN2_FAT = 48, 6


def _tenant_of(sid: int) -> str:
    """Hash-mixed tenant assignment (~50/50): a strict even/odd interleave
    would let a 2-replica round-robin luck into the perfect split by
    parity — the mix must be irregular for the baseline to be honest."""
    return "easy" if (sid * 2654435761) % 97 < 49 else "hard"


def _difficulty(n: int) -> np.ndarray:
    return np.asarray([EASY_DIFF if _tenant_of(i) == "easy" else HARD_DIFF
                       for i in range(n)], np.float32)


def _requests(n: int, n_tokens: int, slo: str = "standard",
              arrivals=None) -> List[Request]:
    return [Request(sample_id=i, prompt=np.full((_S,), i, np.int32),
                    n_tokens=n_tokens, tenant=_tenant_of(i), slo_class=slo,
                    arrival_time=(0.0 if arrivals is None
                                  else float(arrivals[i])))
            for i in range(n)]


def _expected(sids, n_tokens: int) -> dict:
    return {i: [token_of(i, t) for t in range(n_tokens)] for i in sids}


def _fleet(fns_pair, n_slots: int, max_len: int, policy: str,
           max_queue: int = 4) -> FleetRouter:
    """A fresh 2-replica fleet: replica 0 exit-heavy (bucket sized for
    p=0.12 -> capacity 1 at 6 slots, slow per-row stage 2), replica 1 fat
    (p=0.85 -> capacity 6, fast stage 2). One shared clock; a bounded
    per-replica router queue keeps admission incremental, so the
    drift_aware policy routes most requests AFTER the tenant difficulty
    estimates have converged from early finishes."""
    clock = Clock()
    caps = [max(1, int(np.ceil(p * n_slots))) for p in (P_EXIT_HEAVY, P_FAT)]
    replicas = [
        ContinuousScheduler(fns, SL.ServeConfig(capacity=c, queue_depth=4,
                                                c_thr=C_THR),
                            n_slots=n_slots, max_len=max_len, clock=clock)
        for fns, c in zip(fns_pair, caps)]
    return FleetRouter(replicas, policy=policy,
                       provisioned_p=[P_EXIT_HEAVY, P_FAT],
                       max_queue_per_replica=max_queue)


def _one_pass(fns_pair, n: int, n_tokens: int, n_slots: int, max_len: int,
              policy: str, arrivals=None):
    """One timed pass: goodput (tok/s) + the router, streams asserted
    against the analytic oracle. With a two-phase ``arrivals`` trace the
    goodput is measured over the BURST phase only (tokens of
    burst-arrival requests / wall from burst start to drain): the paced
    learning phase is deliberately low-occupancy, so folding it in would
    measure pacing, not routing."""
    router = _fleet(fns_pair, n_slots, max_len, policy)
    for r in _requests(n, n_tokens, arrivals=arrivals):
        router.submit(r)
    results = router.run()
    makespan = router.clock.now()
    assert results == _expected(range(n), n_tokens), \
        f"{policy}: fleet token streams diverged from the analytic oracle"
    if arrivals is None:
        n_tok = sum(len(v) for v in results.values())
        return n_tok / makespan, router
    t_burst = float(arrivals[-1])
    n_burst = int(np.sum(np.asarray(arrivals) >= t_burst))
    return n_burst * n_tokens / (makespan - t_burst), router


def _oracle_results(fns, n: int, n_tokens: int, n_slots: int,
                    max_len: int) -> dict:
    """The single-scheduler oracle: the same requests through ONE
    continuous scheduler — the reference the fleet must match exactly."""
    sched = ContinuousScheduler(
        fns, SL.ServeConfig(capacity=max(1, n_slots // 2), queue_depth=4,
                            c_thr=C_THR),
        n_slots=2 * n_slots, max_len=max_len)
    for r in _requests(n, n_tokens):
        sched.submit(r)
    return sched.run()


def _chaos_pass(fns_pair, n: int, n_tokens: int, n_slots: int,
                max_len: int):
    """The untimed contract pass: batch-class flood, mid-trace gold burst
    (forces preemption of queued batch requests), one forced replica
    degrade (forces queue redistribution). Returns (results, router)."""
    router = _fleet(fns_pair, n_slots, max_len, "drift_aware", max_queue=1)
    n_gold = max(2, n // 4)
    batch_reqs = _requests(n, n_tokens, slo="batch")[n_gold:]
    gold_reqs = _requests(n, n_tokens, slo="gold")[:n_gold]
    for r in batch_reqs:
        router.submit(r)
    # fill pools and queues with batch traffic before gold arrives —
    # but stop BEFORE the first finish (a request needs n_tokens ticks),
    # so the replica queues still hold unadmitted batch victims
    for _ in range(min(n_tokens - 2, 4 + 2 * n_slots)):
        if router.step() == "idle":
            break
    for r in gold_reqs:                      # the high-priority burst
        router.submit(r)
    for _ in range(3):
        router.step()
    router.degrade_replica(0)                # mid-trace replica loss
    results = router.run()
    return results, router


def run(fast: bool = False, iters: Optional[int] = None) -> dict:
    if fast:
        n, n_tokens, n_slots = 48, 10, 6
    else:
        n, n_tokens, n_slots = 80, 14, 6
    iters = iters if iters is not None else (2 if fast else 3)
    max_len = _S + n_tokens
    diff = _difficulty(n)
    fns_pair = (drift_fns(diff, d_model=_D_MODEL,
                          burn2=_BURN2_EXIT_HEAVY),
                drift_fns(diff, d_model=_D_MODEL, burn2=_BURN2_FAT))

    # warmup compiles every program (fns shared across passes => shared
    # jit caches) and measures the closed-loop service rate
    warm_g = min(_one_pass(fns_pair, n, n_tokens, n_slots, max_len, p)[0]
                 for p in ("round_robin", "drift_aware"))
    # two-phase trace, identical for both policies: the first quarter
    # arrives paced (~50% of the measured service rate), so early
    # finishes teach the router each tenant's difficulty while the fleet
    # is live; the rest arrives as one burst, so the bulk of the trace is
    # CAPACITY-bound — goodput then measures how well each policy matches
    # traffic to provisioning, not the arrival rate (machine-adaptive
    # pacing keeps the regime comparable across hosts)
    n_pace = max(n_slots + 2, n // 6)
    paced = poisson_arrivals(n_pace, warm_g / n_tokens, seed=7)
    arrivals = np.concatenate(
        [paced, np.full(n - n_pace, float(paced[-1]), np.float64)])

    ratios, best = [], {}
    for _ in range(iters):
        tps = {}
        for policy in ("round_robin", "drift_aware"):
            g, router = _one_pass(fns_pair, n, n_tokens, n_slots, max_len,
                                  policy, arrivals=arrivals)
            tps[policy] = g
            if g > best.get(policy, (0.0, None))[0]:
                best[policy] = (g, router)
        ratios.append(tps["drift_aware"] / tps["round_robin"])
    ratio = float(np.median(ratios))

    oracle = _oracle_results(fns_pair[1], n, n_tokens, n_slots, max_len)
    fleet_equivalence = best["drift_aware"][1].results == oracle

    chaos_results, chaos_router = _chaos_pass(fns_pair, n, n_tokens,
                                              n_slots, max_len)
    cd = chaos_router.stats.as_dict()
    degrade_equivalence = chaos_results == _expected(range(n), n_tokens)
    preemption_exercised = cd["n_preemptions"] >= 1
    dropped = cd["n_dropped"]

    rows = []
    for policy in ("round_robin", "drift_aware"):
        g, router = best[policy]
        d = router.stats.as_dict()
        reps = d["replicas"]
        rows.append([
            policy, f"{g:,.0f}",
            " / ".join(f"{r['realized_q']:.2f}" for r in reps),
            " / ".join(str(r["n_stalls"]) for r in reps),
            " / ".join(str(r["n_finished"]) for r in reps),
        ])
    txt = table(
        f"Fleet routing: bimodal tenants over asymmetric replicas (N={n}, "
        f"T={n_tokens}, slots={n_slots}/replica, p=[{P_EXIT_HEAVY}, "
        f"{P_FAT}], backend={jax.default_backend()})",
        ["policy", "goodput tok/s", "replica q", "stalls", "finished"],
        rows)
    txt += (f"\ndrift_aware/round_robin {ratio:.2f}x | fleet equiv "
            f"{fleet_equivalence} | degrade equiv {degrade_equivalence} | "
            f"preemptions {cd['n_preemptions']} | requeued "
            f"{cd['n_requeued']} | dropped {dropped}")
    return {
        "text": txt,
        "goodput_round_robin": best["round_robin"][0],
        "goodput_drift_aware": best["drift_aware"][0],
        "drift_aware_vs_rr_goodput_ratio": ratio,
        "fleet_equivalence": bool(fleet_equivalence),
        "degrade_equivalence": bool(degrade_equivalence),
        "preemption_exercised": bool(preemption_exercised),
        "n_preemptions": cd["n_preemptions"],
        "n_requeued": cd["n_requeued"],
        "dropped_requests": dropped,
    }


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--iters", type=int, default=None)
    a = ap.parse_args()
    print(run(fast=a.fast, iters=a.iters)["text"])
