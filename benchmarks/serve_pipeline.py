"""Serving-pipeline throughput: host-loop vs device-resident server.

Measures end-to-end samples/sec of the seed's per-sample host-loop server
(``runtime.serve_loop.HostLoopServer``: per-row host syncs + Python deque +
per-bucket restacking) against the device-resident ``TwoStageServer``
(fused exit decision + compaction through ``kernels.dispatch``, device ring
buffer, async bucket drains) across hard-sample rates q ∈ {0.1, 0.3, 0.5}.
C_thr is calibrated per q on the exit-head confidences so realized q matches
the target, and the stage-2 bucket is sized at ceil(q·B) — the paper's
matched p=q operating point.

Both servers share the same jitted stage callables, so the delta is purely
the exit machinery — the thing ATHEENA keeps on-chip. Run via
``PYTHONPATH=src python -m benchmarks.run --only serve_pipeline [--json]``.

When >= 2 devices are visible (CI runs under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``), each q also
builds the STAGE-DISAGGREGATED server — stage 1 / stage 2 on disjoint
submeshes, chips apportioned q-proportionally unless ``--chips1/--chips2``
override — and enforces bitwise parity against the single-device server
BEFORE timing; per-stage device counts and occupancy ride in the ``--json``
envelope so the perf trajectory captures the apportionment.
"""
from __future__ import annotations

import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import table
from repro.core import early_exit as ee
from repro.core import exit_decision as ed
from repro.core.stage_mesh import StageMeshPlan
from repro.models.config import ArchConfig
from repro.runtime import serve_loop as SL
from repro.runtime.stage_executor import StagePlacement

Q_GRID = (0.1, 0.3, 0.5)


def make_disagg_placement(p: float, chips1: Optional[int] = None,
                          chips2: Optional[int] = None
                          ) -> Optional[StagePlacement]:
    """Disaggregated placement for the parity gate: explicit chip counts
    when given, else the p-proportional apportionment. None when the host
    exposes a single device (the check is then vacuous and recorded so)."""
    n = jax.device_count()
    if n < 2:
        return None
    return StagePlacement.from_plan(StageMeshPlan.resolve(p, n, chips1,
                                                          chips2))


def _bench_cfg() -> ArchConfig:
    """Small enough that the exit machinery (the thing under test) is a
    visible share of the batch period on CPU; the model compute itself is
    identical between the two servers."""
    return ArchConfig(
        name="serve-bench", family="dense", n_layers=4, d_model=32,
        n_heads=4, n_kv_heads=2, d_ff=64, vocab=256,
        dtype="float32", param_dtype="float32", tie_embeddings=True,
    )


def _time_serve(make_server, toks: np.ndarray, batch: int, iters: int
                ) -> tuple:
    """Best-of-iters wall time over the whole token set (fresh server per
    iteration; the jitted stage fns are shared, so no recompilation)."""
    SL.serve_dataset(make_server(), toks[:2 * batch], batch=batch)  # warmup
    best, stats = float("inf"), None
    for _ in range(iters):
        server = make_server()
        t0 = time.perf_counter()
        results = SL.serve_dataset(server, toks, batch=batch)
        best = min(best, time.perf_counter() - t0)
        stats = server.stats
        assert len(results) == toks.shape[0], "dropped requests"
    return toks.shape[0] / best, stats


def run(fast: bool = False, chips1: Optional[int] = None,
        chips2: Optional[int] = None) -> dict:
    n = 512 if fast else 1024
    batch, seq = 128, 16
    iters = 2 if fast else 3
    cfg = _bench_cfg()
    spec0 = ee.EarlyExitSpec(exit_layer=2, c_thr=0.5)
    params = ee.init_ee_params(jax.random.PRNGKey(0), cfg, spec0)
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (n, seq), 0,
                                         cfg.vocab))
    _, _, exit_logits, _ = ee.stage1_prefill(params, cfg, spec0,
                                             jnp.asarray(toks))
    conf = ed.softmax_confidence(exit_logits)

    n_dev = jax.device_count()
    rows, data = [], {}
    all_parity = True
    for q in Q_GRID:
        # C_thr at the q-quantile of confidence => a q fraction stays hard
        c_thr = float(jnp.quantile(conf, q))
        spec = ee.EarlyExitSpec(exit_layer=spec0.exit_layer, c_thr=c_thr)
        capacity = max(8, int(np.ceil(q * batch)))
        sc = SL.ServeConfig(capacity=capacity, queue_depth=4, c_thr=c_thr)
        s1, s2 = SL._stage_fns(params, cfg, spec)

        # disaggregated parity gate BEFORE timing: the submesh server must
        # reproduce the single-device server bit for bit (ATHEENA's spatial
        # apportionment must not change answers)
        placement = make_disagg_placement(q, chips1, chips2)
        c1 = placement.ex1.n_devices if placement else 1
        c2 = placement.ex2.n_devices if placement else 1
        occ = {}
        parity = True
        if placement is not None:
            sub = toks[:2 * batch]
            dis = SL.build_server(params, cfg, spec, sc, placement)
            r_dis = SL.serve_dataset(dis, sub, batch=batch)
            r_one = SL.serve_dataset(SL.TwoStageServer(s1, s2, sc), sub,
                                     batch=batch)
            parity = (set(r_dis) == set(r_one) and all(
                np.array_equal(r_dis[i], r_one[i]) for i in r_one))
            assert parity, f"disaggregated parity broke at q={q}"
            occ = {"stage1_occupancy": dis.stats.stage1_occupancy,
                   "stage2_occupancy": dis.stats.stage2_occupancy}
        all_parity &= parity

        host_sps, host_stats = _time_serve(
            lambda: SL.HostLoopServer(s1, s2, sc), toks, batch, iters)
        dev_sps, dev_stats = _time_serve(
            lambda: SL.TwoStageServer(s1, s2, sc), toks, batch, iters)
        speedup = dev_sps / host_sps
        rows.append([f"{q:.1f}", f"{dev_stats.realized_q:.2f}", capacity,
                     f"{host_sps:,.0f}", f"{dev_sps:,.0f}",
                     f"{speedup:.2f}x",
                     f"{dev_stats.mean_bucket_fill:.2f}",
                     f"{c1}+{c2}" if placement else "-"])
        data[f"q{q}"] = {"host_sps": host_sps, "device_sps": dev_sps,
                         "speedup": speedup,
                         "realized_q": dev_stats.realized_q,
                         "chips1": c1, "chips2": c2,
                         **occ}

    # vacuously true on a 1-device host; CI pins 8 host devices so the
    # gate (benchmarks/compare.py) always sees the real check
    data["disagg"] = {"devices": n_dev, "checked": n_dev >= 2,
                      "parity": bool(all_parity)}
    txt = table(
        "Serving pipeline: host-loop vs device-resident "
        f"(B={batch}, S={seq}, N={n}, backend={jax.default_backend()}, "
        f"devices={n_dev})",
        ["q", "realized q", "bucket C", "host sps", "device sps", "speedup",
         "bucket fill", "submesh"], rows)
    return {"text": txt, **data}


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--chips1", type=int, default=None,
                    help="stage-1 submesh size (default: plan-derived)")
    ap.add_argument("--chips2", type=int, default=None,
                    help="stage-2 submesh size (default: plan-derived)")
    a = ap.parse_args()
    print(run(fast=a.fast, chips1=a.chips1, chips2=a.chips2)["text"])
