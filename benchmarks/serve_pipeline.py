"""Serving-pipeline throughput: host-loop vs device-resident server.

Measures end-to-end samples/sec of the seed's per-sample host-loop server
(``runtime.serve_loop.HostLoopServer``: per-row host syncs + Python deque +
per-bucket restacking) against the device-resident ``TwoStageServer``
(fused exit decision + compaction through ``kernels.dispatch``, device ring
buffer, async bucket drains) across hard-sample rates q ∈ {0.1, 0.3, 0.5}.
C_thr is calibrated per q on the exit-head confidences so realized q matches
the target, and the stage-2 bucket is sized at ceil(q·B) — the paper's
matched p=q operating point.

Both servers share the same jitted stage callables, so the delta is purely
the exit machinery — the thing ATHEENA keeps on-chip. Run via
``PYTHONPATH=src python -m benchmarks.run --only serve_pipeline [--json]``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import table
from repro.core import early_exit as ee
from repro.core import exit_decision as ed
from repro.models.config import ArchConfig
from repro.runtime import serve_loop as SL

Q_GRID = (0.1, 0.3, 0.5)


def _bench_cfg() -> ArchConfig:
    """Small enough that the exit machinery (the thing under test) is a
    visible share of the batch period on CPU; the model compute itself is
    identical between the two servers."""
    return ArchConfig(
        name="serve-bench", family="dense", n_layers=4, d_model=32,
        n_heads=4, n_kv_heads=2, d_ff=64, vocab=256,
        dtype="float32", param_dtype="float32", tie_embeddings=True,
    )


def _time_serve(make_server, toks: np.ndarray, batch: int, iters: int
                ) -> tuple:
    """Best-of-iters wall time over the whole token set (fresh server per
    iteration; the jitted stage fns are shared, so no recompilation)."""
    SL.serve_dataset(make_server(), toks[:2 * batch], batch=batch)  # warmup
    best, stats = float("inf"), None
    for _ in range(iters):
        server = make_server()
        t0 = time.perf_counter()
        results = SL.serve_dataset(server, toks, batch=batch)
        best = min(best, time.perf_counter() - t0)
        stats = server.stats
        assert len(results) == toks.shape[0], "dropped requests"
    return toks.shape[0] / best, stats


def run(fast: bool = False) -> dict:
    n = 512 if fast else 1024
    batch, seq = 128, 16
    iters = 2 if fast else 3
    cfg = _bench_cfg()
    spec0 = ee.EarlyExitSpec(exit_layer=2, c_thr=0.5)
    params = ee.init_ee_params(jax.random.PRNGKey(0), cfg, spec0)
    toks = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (n, seq), 0,
                                         cfg.vocab))
    _, _, exit_logits, _ = ee.stage1_prefill(params, cfg, spec0,
                                             jnp.asarray(toks))
    conf = ed.softmax_confidence(exit_logits)

    rows, data = [], {}
    for q in Q_GRID:
        # C_thr at the q-quantile of confidence => a q fraction stays hard
        c_thr = float(jnp.quantile(conf, q))
        spec = ee.EarlyExitSpec(exit_layer=spec0.exit_layer, c_thr=c_thr)
        capacity = max(8, int(np.ceil(q * batch)))
        sc = SL.ServeConfig(capacity=capacity, queue_depth=4, c_thr=c_thr)
        s1, s2 = SL._stage_fns(params, cfg, spec)
        host_sps, host_stats = _time_serve(
            lambda: SL.HostLoopServer(s1, s2, sc), toks, batch, iters)
        dev_sps, dev_stats = _time_serve(
            lambda: SL.TwoStageServer(s1, s2, sc), toks, batch, iters)
        speedup = dev_sps / host_sps
        rows.append([f"{q:.1f}", f"{dev_stats.realized_q:.2f}", capacity,
                     f"{host_sps:,.0f}", f"{dev_sps:,.0f}",
                     f"{speedup:.2f}x",
                     f"{dev_stats.mean_bucket_fill:.2f}"])
        data[f"q{q}"] = {"host_sps": host_sps, "device_sps": dev_sps,
                         "speedup": speedup,
                         "realized_q": dev_stats.realized_q}

    txt = table(
        "Serving pipeline: host-loop vs device-resident "
        f"(B={batch}, S={seq}, N={n}, backend={jax.default_backend()})",
        ["q", "realized q", "bucket C", "host sps", "device sps", "speedup",
         "bucket fill"], rows)
    return {"text": txt, **data}


if __name__ == "__main__":
    print(run()["text"])
