"""Nonstationary decode serving: the closed-loop drift controller vs an
uncontrolled server and a q-oracle, under piecewise/ramped exit-rate
traces.

ATHEENA provisions the stage mesh for a measured exit probability p; when
the live input distribution drifts, the realized hard rate q leaves the
provisioned point and an uncontrolled server pays the Fig. 4 off-design
penalty — here, stage-2 buckets saturate, the ring backpressures stage 1,
and goodput collapses toward the p/q band. The drift controller
(``runtime/controller.py``) senses the drift from the per-dispatch q
series, re-solves C_thr from its rolling confidence reservoir, and steers
the realized exit rate back to the provisioned p.

**The workload is semi-synthetic, deliberately.** ``drift_fns`` builds a
``DecodeFns`` whose exit-head confidences are an ANALYTIC function of
(sample id, decode index) around a per-sample difficulty knob — so the
input distribution, and with it the hard rate at any fixed threshold, is a
known, deterministic function of arrival order (a piecewise-constant phase
A, a linear ramp, a shifted phase C). Each stage still performs real
jitted matmul work (stage 2 several times stage 1's, mirroring the deep
half), so hard tokens carry real wall cost through the real scheduler,
ring and bucket machinery. A real model would confound the controller's
effect with whatever its confidence distribution happens to do; the
analytic stream makes the drift — and the recovery — attributable.

Three passes over the SAME request trace (fresh scheduler each):

  * **uncontrolled** — C_thr fixed at the phase-A calibration (what a
    PR-4 server does when the world moves);
  * **controlled** — ``DriftController`` attached (threshold
    re-calibration + autoscaler; re-plan report-only);
  * **q-oracle** — C_thr switched to each phase's exact offline-calibrated
    value as the admission front crosses the phase boundary: the
    information-unlimited upper bound the controller chases.

Tracked metrics (hard-gated in ``benchmarks/compare.py``):

  * ``controlled_vs_uncontrolled_goodput_ratio`` — median paired ratio,
    hard ``min`` bound;
  * ``gap_recovery`` — (controlled - uncontrolled) / (oracle -
    uncontrolled) goodput, >= 0.5 means the controller recovers most of
    what drift cost;
  * ``converged_q_err`` — |mean realized q over the trailing ticks - p|
    of the controlled pass, <= 0.05: the re-calibrated threshold holds
    the realized exit rate at the provisioned point.

Run via ``PYTHONPATH=src python -m benchmarks.run --only serve_drift
[--json]``.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import table
from repro.runtime import serve_loop as SL
from repro.runtime.controller import ControllerConfig, DriftController
from repro.runtime.scheduler import ContinuousScheduler, Request

_VOCAB = 64
_S = 4                      # prompt length (sid is encoded in the prompt)
_CONF_LO, _CONF_HI = 0.05, 0.98
_SPREAD = 0.18              # half-width of the per-token confidence jitter

PROVISIONED_P = 0.25


def token_of(sid: int, t: int) -> int:
    """The analytic greedy token stream (independent of the exit path, so
    any scheduling/actuation interleaving must reproduce it exactly)."""
    return (3 + sid * 31 + t * 7) % _VOCAB


def conf_of(sid, t, difficulty):
    """Deterministic per-token exit confidence: the sample's difficulty
    plus a hash jitter — numpy/jnp polymorphic (the benchmark computes
    phase populations with the SAME expression the stage fns trace)."""
    u = ((sid * 9973 + t * 131) % 4096) / 4096.0
    raw = difficulty + _SPREAD * (u - 0.5)
    if isinstance(raw, jnp.ndarray):
        return jnp.clip(raw, _CONF_LO, _CONF_HI)
    return np.clip(raw, _CONF_LO, _CONF_HI)


def drift_fns(difficulty: np.ndarray, d_model: int = 96, burn1: int = 2,
              burn2: int = 16) -> SL.DecodeFns:
    """A ``DecodeFns`` with analytic confidences/tokens and real matmul
    burn: stage 1 applies ``burn1`` (d, d) matmuls per tick, stage 2
    ``burn2`` per bucket row — the deep-half cost asymmetry that makes a
    drifted hard rate expensive. The sample id rides the stage-1 cache and
    the stage-2 row payload (exactly like the scheduler property tests'
    toy fns), so the full ring/bucket machinery is exercised.

    Exit logits are ``z * one_hot(token)`` with z solved so the row's
    max-softmax confidence is EXACTLY ``conf_of(sid, t, difficulty[sid])``
    (a uniform logit shift from the burn keeps softmax — and thus every
    decision — invariant while forcing XLA to keep the burn)."""
    diff = jnp.asarray(difficulty, jnp.float32)
    key = jax.random.PRNGKey(1234)
    w1 = jax.random.normal(key, (d_model, d_model), jnp.float32) * 0.2
    w2 = jax.random.normal(jax.random.fold_in(key, 1),
                           (d_model, d_model), jnp.float32) * 0.2

    def _burn(x0, w, n):
        x = x0
        for _ in range(n):
            x = jnp.tanh(x @ w)
        # a data-dependent scalar: added uniformly to every logit it
        # shifts softmax by nothing, but XLA cannot fold the burn away
        return jnp.sum(x) * 1e-6

    def _logits(sid, t):
        conf = conf_of(sid, t, jnp.take(diff, sid))
        z = jnp.log(conf * (_VOCAB - 1) / (1.0 - conf))
        tok = (3 + sid * 31 + t * 7) % _VOCAB
        return z[:, None] * jax.nn.one_hot(tok, _VOCAB, dtype=jnp.float32)

    def prefill(prompts, max_len):
        sid = prompts[:, 0].astype(jnp.int32)
        caches = {"first": [sid[:, None]], "blocks": (), "rem": []}
        tok0 = (3 + sid * 31) % _VOCAB
        return 50.0 * jax.nn.one_hot(tok0, _VOCAB, dtype=jnp.float32), caches

    def split(caches):
        return caches, {"sid": caches["first"][0]}

    def s1_raw(tok, c1, pos):
        sid = c1["first"][0][:, 0]
        t = pos - _S + 1                    # decode index being produced
        x = jnp.broadcast_to(tok.astype(jnp.float32), (tok.shape[0], d_model))
        shift = _burn(x, w1, burn1)
        return x, c1, _logits(sid, t) + shift

    def s2(h_rows, cache_rows, step):
        sid = cache_rows["sid"][:, 0]
        shift = _burn(h_rows, w2, burn2)
        return _logits(sid, step - _S + 1) + shift, cache_rows

    return SL.DecodeFns(prefill, split, jax.jit(s1_raw), jax.jit(s2), s1_raw)


# ---------------------------------------------------------------------------
# the nonstationary difficulty trace: piecewise phase A -> linear ramp ->
# shifted phase C (arrival order IS the time axis: requests are admitted
# in sid order)
# ---------------------------------------------------------------------------

def difficulty_trace(n: int, easy: float = 0.78, hard: float = 0.48
                     ) -> np.ndarray:
    """Per-sample difficulty over arrival order: the first quarter sits at
    the calibration-time distribution, the next quarter ramps down (the
    input stream getting harder), the back half holds the shifted
    distribution — a piecewise + ramped q trace at any fixed threshold,
    with enough post-shift runway for the convergence bar to measure a
    settled operating point rather than the transient."""
    a, b = n // 4, n // 2
    d = np.empty(n, np.float32)
    d[:a] = easy
    d[a:b] = np.linspace(easy, hard, b - a, dtype=np.float32)
    d[b:] = hard
    return d


def phase_threshold(difficulty: np.ndarray, sids, n_tokens: int,
                    p: float) -> float:
    """Offline-exact calibration for a set of samples: the threshold whose
    exit rate over those samples' full token population is 1 - p."""
    conf = np.concatenate([
        conf_of(np.asarray(sids), t, difficulty[np.asarray(sids)])
        for t in range(1, n_tokens)])
    return float(np.quantile(conf, p))


class OracleThreshold:
    """The q-oracle 'controller': switches C_thr to each phase's exact
    offline calibration as the admission front crosses the phase boundary.
    It consumes ground truth the real controller must estimate — the
    information-unlimited upper bound."""

    def __init__(self, boundaries: List[int], thresholds: List[float],
                 n_slots: int):
        self.boundaries = boundaries        # ascending sid cut points
        self.thresholds = thresholds        # len(boundaries) + 1 values
        self.n_slots = n_slots

    def on_tick(self, sched, n_decisions, n_hard, confidences=None) -> None:
        front = max(0, sched.stats.n_samples - self.n_slots // 2)
        phase = sum(front >= b for b in self.boundaries)
        sched.set_c_thr(self.thresholds[phase])


# ---------------------------------------------------------------------------
# passes
# ---------------------------------------------------------------------------

def _requests(n: int, n_tokens: int) -> List[Request]:
    return [Request(sample_id=i, prompt=np.full((_S,), i, np.int32),
                    n_tokens=n_tokens) for i in range(n)]


def _one_pass(fns, sc, n, n_tokens, n_slots, max_len, attach=None):
    """One pass over the trace on a fresh scheduler; ``attach`` wires a
    controller (or oracle) before any request is admitted. Returns
    (goodput tok/s, scheduler)."""
    sched = ContinuousScheduler(fns, sc, n_slots=n_slots, max_len=max_len)
    if attach is not None:
        attach(sched)
    for r in _requests(n, n_tokens):
        sched.submit(r)
    results = sched.run()
    makespan = sched.clock.now()
    n_tok = sum(len(v) for v in results.values())
    assert all(v == [token_of(i, t) for t in range(n_tokens)]
               for i, v in results.items()), "token streams diverged"
    return n_tok / makespan, sched


def make_controller(p: float = PROVISIONED_P) -> DriftController:
    """The benchmark's controller configuration: a small reservoir (~16
    ticks of live-row confidences, so the calibration set tracks the
    current regime instead of averaging over dead phases) and short
    warmup/cooldowns so the loop converges within a CI-sized trace;
    re-plan stays report-only (no mid-pass recompiles in the timed
    comparison)."""
    return DriftController(ControllerConfig(
        provisioned_p=p, target_band=0.05, release_band=0.02,
        replan_band=0.2, min_decisions=48, persistence_ticks=2,
        cooldown_ticks=2, max_thr_step=0.2, reservoir_size=96,
        min_reservoir=48, apply_replan=False))


def tail_q(sched, window: int = 32) -> float:
    """Mean realized q over the trailing ticks — the post-convergence
    operating point the acceptance bar measures."""
    series = list(sched.stats.realized_q_series)[-window:]
    return float(np.mean(series)) if series else 0.0


def run(fast: bool = False, iters: Optional[int] = None) -> dict:
    p = PROVISIONED_P
    if fast:
        n, n_tokens, n_slots = 128, 16, 8
    else:
        n, n_tokens, n_slots = 192, 20, 8
    iters = iters if iters is not None else 5
    max_len = _S + n_tokens
    capacity = max(1, int(np.ceil(p * n_slots)))
    diff = difficulty_trace(n)
    fns = drift_fns(diff)

    a, b = n // 4, n // 2
    thr0 = phase_threshold(diff, range(0, a), n_tokens, p)
    thr_ramp = phase_threshold(diff, range(a, b), n_tokens, p)
    thr_c = phase_threshold(diff, range(b, n), n_tokens, p)
    sc = SL.ServeConfig(capacity=capacity, queue_depth=4, c_thr=thr0)

    def oracle_attach(sched):
        sched.controller = OracleThreshold([a, b], [thr0, thr_ramp, thr_c],
                                           n_slots)

    def controlled_attach(sched):
        make_controller(p).attach(sched)

    passes = (("uncontrolled", None), ("controlled", controlled_attach),
              ("oracle", oracle_attach))
    # warmup (compiles all programs; c_thr is traced so every pass shares
    # them), then paired timed iterations — all three variants run back to
    # back within an iteration so runner drift hits each side alike
    for _, attach in passes:
        _one_pass(fns, sc, n, n_tokens, n_slots, max_len, attach)
    best = {name: (0.0, None) for name, _ in passes}
    ratios, recoveries = [], []
    for _ in range(iters):
        tps = {}
        for name, attach in passes:
            g, sched = _one_pass(fns, sc, n, n_tokens, n_slots, max_len,
                                 attach)
            tps[name] = g
            if g > best[name][0]:
                best[name] = (g, sched)
        ratios.append(tps["controlled"] / tps["uncontrolled"])
        gap = tps["oracle"] - tps["uncontrolled"]
        # iterations where noise erased the oracle-vs-uncontrolled gap
        # carry no recovery information — dropping them (instead of
        # recording a fake 1.0) keeps the hard-gated metric meaningful;
        # if EVERY iteration lost its gap the recovery is NaN, which the
        # perf gate fails loudly
        if gap > 0:
            recoveries.append((tps["controlled"] - tps["uncontrolled"])
                              / gap)
    ratio = float(np.median(ratios))
    recovery = float(np.median(recoveries)) if recoveries else float("nan")

    unctrl_sched = best["uncontrolled"][1]
    ctrl_sched = best["controlled"][1]
    ctl = ctrl_sched.controller
    ctl_state = ctl.state
    # the convergence bar: decision-WEIGHTED realized q over the trailing
    # span (per-tick q is occupancy-biased during the final drain)
    q_tail_ctrl = ctl.realized_q_tail()
    q_tail_unctrl = tail_q(unctrl_sched)
    converged_q_err = abs(q_tail_ctrl - p)

    rows = [
        ["uncontrolled", f"{best['uncontrolled'][0]:,.0f}",
         f"{unctrl_sched.stats.realized_q:.2f}", f"{q_tail_unctrl:.2f}",
         unctrl_sched.stats.n_stalls, "-"],
        ["controlled", f"{best['controlled'][0]:,.0f}",
         f"{ctrl_sched.stats.realized_q:.2f}", f"{q_tail_ctrl:.2f}",
         ctrl_sched.stats.n_stalls, ctl_state.n_recalibrations],
        ["q-oracle", f"{best['oracle'][0]:,.0f}",
         f"{best['oracle'][1].stats.realized_q:.2f}",
         f"{tail_q(best['oracle'][1]):.2f}",
         best["oracle"][1].stats.n_stalls, "-"],
    ]
    txt = table(
        f"Drift control: nonstationary q trace (N={n}, T={n_tokens}, "
        f"slots={n_slots}, p={p}, C={capacity}, thr0={thr0:.3f}, "
        f"backend={jax.default_backend()})",
        ["server", "goodput tok/s", "lifetime q", "tail q", "stalls",
         "recals"], rows)
    txt += (f"\ncontrolled/uncontrolled {ratio:.2f}x | gap recovery "
            f"{recovery:.2f} | tail |q - p| {converged_q_err:.3f}")
    return {
        "text": txt,
        "goodput_uncontrolled": best["uncontrolled"][0],
        "goodput_controlled": best["controlled"][0],
        "goodput_oracle": best["oracle"][0],
        "controlled_vs_uncontrolled_goodput_ratio": ratio,
        "gap_recovery": recovery,
        "converged_q_err": converged_q_err,
        "uncontrolled_tail_q": q_tail_unctrl,
        "controlled_tail_q": q_tail_ctrl,
        "n_recalibrations": ctl_state.n_recalibrations,
        "n_replans": ctl_state.n_replans,
        "final_c_thr": ctl_state.c_thr,
        "oracle_thresholds": [thr0, thr_ramp, thr_c],
    }


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--iters", type=int, default=None)
    a = ap.parse_args()
    print(run(fast=a.fast, iters=a.iters)["text"])
