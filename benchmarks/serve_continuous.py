"""Open-loop decode serving: sync (static batch formation) vs continuous
(slot-based) scheduling under Poisson arrivals.

The ``sync`` policy is the step-synchronous ``DecodeServer`` behind static
batch formation (``SyncScheduler``): requests are admitted in arrival order
into batches of ``n_slots``, and every batch runs in lockstep to its
*longest* request — finished samples ride along, and stage 1 waits for the
ring to drain each step. The ``continuous`` policy
(``runtime.scheduler.ContinuousScheduler``) keeps a fixed slot pool with
per-slot step counters: easy samples keep decoding through stage 1 while
hard tokens wait in the ring for bucketed stage-2 dispatch, and completed
slots are backfilled from the admission queue immediately. Variable
per-request generation lengths make the lockstep waste visible — the
classic continuous-batching win, realized here *on top of* the two-stage
early-exit machinery.

Per q in {0.1, 0.3, 0.5} (C_thr calibrated on the first decode step's
exit-head confidences, bucket capacity ceil(q * n_slots)):

  * token-stream equivalence is enforced BEFORE timing: every sample id's
    continuous greedy stream must be identical to ``HostLoopDecoder``'s
    (the sync policy inherits bitwise parity from ``DecodeServer``) —
    the continuous correctness contract (same tokens per sample, any
    interleaving);
  * goodput = emitted tokens per second of scheduler-clock makespan, and
    the tracked ``goodput_ratio`` = continuous / sync on the SAME machine
    and request trace (machine-robust, gated >= 1.3x at q = 0.3 by
    ``benchmarks/compare.py``);
  * per-request submit->finish latency percentiles (p50/p90/p99) from
    ``ServeStats`` ride in the JSON envelope (noisier than the ratio, so
    untracked by the gate — see the per-metric tolerance machinery).

When >= 2 devices are visible (CI pins 8 host devices), q = 0.3 also runs
the continuous scheduler STAGE-DISAGGREGATED (pool + stage 1 on one
submesh; ring, stage-2 cache store and bucketed vector-step dispatches on
the other) and enforces the same per-sample token equivalence.

Run via ``PYTHONPATH=src python -m benchmarks.run --only serve_continuous
[--json]``.
"""
from __future__ import annotations

import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import table
from benchmarks.serve_pipeline import make_disagg_placement
from repro.core import early_exit as ee
from repro.models.config import ArchConfig
from repro.runtime import serve_loop as SL
from repro.runtime.scheduler import Request, poisson_arrivals

Q_GRID = (0.1, 0.3, 0.5)
ARRIVAL_RATE = 2000.0      # req/s: saturating on any CPU host (interarrival
                           # far below a decode tick), so goodput measures
                           # scheduling, not the arrival process


def _bench_cfg() -> ArchConfig:
    """Small enough that scheduling overhead (the thing under test) is a
    visible share of the step period on CPU; the model compute itself is
    identical between the two policies."""
    return ArchConfig(
        name="serve-cont-bench", family="dense", n_layers=4, d_model=32,
        n_heads=4, n_kv_heads=2, d_ff=64, vocab=256,
        dtype="float32", param_dtype="float32", tie_embeddings=True,
    )


def _make_requests(prompts: np.ndarray, n_tokens: np.ndarray,
                   rate: float, seed: int) -> List[Request]:
    arrivals = poisson_arrivals(len(prompts), rate, seed)
    return [Request(sample_id=i, prompt=prompts[i], n_tokens=int(n_tokens[i]),
                    arrival_time=float(arrivals[i]))
            for i in range(len(prompts))]


def _one_pass(make_sched, reqs: List[Request]):
    """One open-loop pass on a fresh scheduler (its clock starts at pass
    start); returns (goodput tok/s, stats)."""
    sched = make_sched()
    for r in reqs:
        sched.submit(r)
    results = sched.run()
    makespan = sched.clock.now()
    return sum(len(v) for v in results.values()) / makespan, sched.stats


def _run_policies(make_sync, make_cont, reqs: List[Request], iters: int):
    """One warmup pass each (compiles), then ``iters`` PAIRED timed passes:
    sync and continuous run back to back within each pair, so slowly-varying
    runner drift (shared CI boxes) hits both sides of a pair equally. The
    tracked ratio is the MEDIAN of per-pair ratios — unbiased under
    symmetric contention noise (a burst can land on either side of a pair)
    and robust to outlier windows, unlike best-of or the mean; the reported
    tok/s are each policy's best pass."""
    _one_pass(make_sync, reqs)
    _one_pass(make_cont, reqs)
    best = {"sync": (0.0, None), "cont": (0.0, None)}
    ratios = []
    for _ in range(iters):
        pair = {}
        for key, mk in (("sync", make_sync), ("cont", make_cont)):
            tps, stats = _one_pass(mk, reqs)
            pair[key] = tps
            if tps > best[key][0]:
                best[key] = (tps, stats)
        ratios.append(pair["cont"] / pair["sync"])
    return best["sync"], best["cont"], float(np.median(ratios))


def run(fast: bool = False, chips1: Optional[int] = None,
        chips2: Optional[int] = None,
        arrival_rate: float = ARRIVAL_RATE) -> dict:
    # long-tailed generation lengths — the realistic serving regime and the
    # canonical continuous-batching motivation: a static batch runs in
    # lockstep to its longest member, so the tail length sets the whole
    # batch's wall time while most slots sit finished
    seq = 8
    if fast:
        n_requests, n_slots, tok_choices = 24, 8, (3, 4, 6, 24)
    else:
        n_requests, n_slots, tok_choices = 48, 16, (6, 8, 12, 40)
    max_tok = max(tok_choices)
    max_len = seq + max_tok
    cfg = _bench_cfg()
    spec0 = ee.EarlyExitSpec(exit_layer=2, c_thr=0.5)
    params = ee.init_ee_params(jax.random.PRNGKey(0), cfg, spec0)
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (n_requests, seq), 0, cfg.vocab))
    n_tokens = np.random.default_rng(7).choice(tok_choices, size=n_requests)
    conf = SL.decode_step0_confidences(params, cfg, spec0, prompts[:n_slots],
                                       max_len=max_len)
    fns = SL.decode_stage_fns(params, cfg, spec0)

    n_dev = jax.device_count()
    rows, data = [], {}
    all_equiv = True
    dis_checked, dis_equiv = False, True
    for q in Q_GRID:
        c_thr = float(jnp.quantile(conf, q))
        capacity = max(2, int(np.ceil(q * n_slots)))
        sc = SL.ServeConfig(capacity=capacity, queue_depth=4, c_thr=c_thr)
        reqs = _make_requests(prompts, n_tokens, arrival_rate, seed=11)

        # --- correctness gate BEFORE timing: per-sample token equivalence
        # against the host-loop oracle (sync inherits bitwise parity from
        # DecodeServer, checked in serve_decode)
        oracle = SL.HostLoopDecoder(fns, sc).generate(prompts, max_tok)
        cont = SL.ContinuousScheduler(fns, sc, n_slots=n_slots,
                                      max_len=max_len)
        for r in reqs:
            cont.submit(r)
        res = cont.run()
        equiv = all(
            [int(x) for x in oracle["tokens"][i][:int(n_tokens[i])]] == res[i]
            for i in range(n_requests))
        assert equiv, f"continuous token-stream equivalence broke at q={q}"
        all_equiv &= equiv

        # --- disaggregated equivalence (q = 0.3 keeps the bench bounded)
        if q == 0.3:
            placement = make_disagg_placement(q, chips1, chips2)
            if placement is not None:
                dis_checked = True
                spec = ee.EarlyExitSpec(exit_layer=spec0.exit_layer,
                                        c_thr=c_thr)
                dsched = SL.build_continuous_scheduler(
                    params, cfg, spec, sc, n_slots=n_slots, max_len=max_len,
                    placement=placement)
                for r in _make_requests(prompts, n_tokens, arrival_rate, 11):
                    dsched.submit(r)
                dres = dsched.run()
                dis_equiv = all(
                    [int(x) for x in oracle["tokens"][i][:int(n_tokens[i])]]
                    == dres[i] for i in range(n_requests))
                assert dis_equiv, \
                    f"disaggregated continuous equivalence broke at q={q}"

        # --- timed open-loop runs (warmup passes amortize compiles).
        # Fast mode deliberately runs MORE pairs than full mode: it is the
        # CI-gated configuration (the q=0.3 median carries a hard 1.3x
        # floor), so stabilizing its median on contended runners is worth
        # the extra short passes; full-mode passes are ~4x longer, and 5
        # pairs keep its runtime sane.
        iters = 8 if fast else 5
        ((sync_tps, sync_stats), (cont_tps, cont_stats),
         ratio) = _run_policies(
            lambda: SL.SyncScheduler(SL.DecodeServer(fns, sc), n_slots),
            lambda: SL.ContinuousScheduler(fns, sc, n_slots=n_slots,
                                           max_len=max_len),
            reqs, iters)
        rows.append([f"{q:.1f}", f"{cont_stats.realized_q:.2f}", capacity,
                     f"{sync_tps:,.0f}", f"{cont_tps:,.0f}",
                     f"{ratio:.2f}x",
                     f"{sync_stats.latency_p99 * 1e3:,.0f}",
                     f"{cont_stats.latency_p99 * 1e3:,.0f}", equiv])
        data[f"q{q}"] = {
            "sync_goodput": sync_tps, "continuous_goodput": cont_tps,
            "goodput_ratio": ratio, "equivalence": bool(equiv),
            "realized_q": cont_stats.realized_q,
            "sync_latency_p50": sync_stats.latency_p50,
            "sync_latency_p90": sync_stats.latency_p90,
            "sync_latency_p99": sync_stats.latency_p99,
            "continuous_latency_p50": cont_stats.latency_p50,
            "continuous_latency_p90": cont_stats.latency_p90,
            "continuous_latency_p99": cont_stats.latency_p99,
        }

    data["disagg"] = {"devices": n_dev, "checked": dis_checked,
                      "equivalence": bool(dis_equiv)}
    txt = table(
        "Continuous-batching decode: sync vs slot-scheduled "
        f"(N={n_requests}, slots={n_slots}, prompt={seq}, "
        f"T∈{tok_choices}, λ={arrival_rate:g}/s, "
        f"backend={jax.default_backend()}, devices={n_dev})",
        ["q", "realized q", "bucket C", "sync tok/s", "cont tok/s",
         "goodput", "sync p99 ms", "cont p99 ms", "streams =="], rows)
    return {"text": txt, **data}


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--arrival-rate", type=float, default=ARRIVAL_RATE)
    ap.add_argument("--chips1", type=int, default=None,
                    help="stage-1 submesh size (default: plan-derived)")
    ap.add_argument("--chips2", type=int, default=None,
                    help="stage-2 submesh size (default: plan-derived)")
    a = ap.parse_args()
    print(run(fast=a.fast, chips1=a.chips1, chips2=a.chips2,
              arrival_rate=a.arrival_rate)["text"])
