"""Table IV analogue — throughput improvement of two-stage ATHEENA designs
vs baselines across networks: the paper's three CNNs at the paper's p
values, PLUS the assigned LM architectures (serving, prefill shape) under
the TPU chip-budget TAP model."""
from __future__ import annotations

from benchmarks.common import table
from repro.core import dse
from repro.models.cnn import b_alexnet, b_lenet, triple_wins_lenet
from repro.models.registry import get_arch

PAPER_ROWS = [
    (b_lenet, 0.25, "MNIST", "2.17x"),
    (triple_wins_lenet, 0.25, "MNIST", "2.78x"),
    (b_alexnet, 0.34, "CIFAR10", "2.00x"),
]
LM_ROWS = [("qwen2-1.5b", 0.25), ("qwen2-7b", 0.25),
           ("deepseek-v2-lite-16b", 0.25), ("grok-1-314b", 0.25)]


def run(n_seeds: int = 3) -> dict:
    rows, gains = [], {}
    for mk, p, task, paper_gain in PAPER_ROWS:
        cfg = mk()
        des = dse.atheena_optimize_cnn(cfg, p=p, budget=256, n_seeds=n_seeds)
        g = des.gain_vs_baseline()
        gains[cfg.name] = g
        rows.append([cfg.name, task, f"{p:.0%}",
                     f"{des.combined.design_throughput:,.0f}",
                     f"{g:.2f}x", paper_gain])
    for arch, p in LM_ROWS:
        cfg = get_arch(arch)
        k = cfg.default_exit_layers()[0]
        try:
            des = dse.atheena_optimize_lm(cfg, k, p, kind="prefill",
                                          seq_len=4096, batch=256, chips=256)
            g = des.gain_vs_baseline()
            gains[arch] = g
            rows.append([arch, "LM prefill 4k", f"{p:.0%}",
                         f"{des.combined.design_throughput:,.0f}",
                         f"{g:.2f}x", "-"])
        except RuntimeError as e:
            rows.append([arch, "LM prefill 4k", f"{p:.0%}", "-",
                         f"infeasible: {e}", "-"])
    txt = table("Table IV — ATHEENA gain vs baseline per network "
                "(model-predicted; paper band 2.00-2.78x for its CNNs)",
                ["network", "task", "p", "thr (samples/s)", "gain",
                 "paper"], rows)
    return {"text": txt, "gains": gains}


def main() -> None:
    print(run()["text"])


if __name__ == "__main__":
    main()
