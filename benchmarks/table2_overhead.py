"""Table II analogue — Early-Exit overhead: the resource share attributable
to the *additional* EE machinery (exit classifier layers + exit decision +
conditional buffering) vs the backbone, for the paper's CNNs and the LM
architectures (exit head + decision + compaction FLOPs/bytes)."""
from __future__ import annotations

from benchmarks.common import table
from repro.core import early_exit as ee
from repro.core import perf_model as pm
from repro.models.cnn import b_alexnet, b_lenet, triple_wins_lenet
from repro.models.registry import get_arch

LM_ARCHS = ("qwen2-1.5b", "qwen2-7b", "deepseek-v2-lite-16b", "grok-1-314b")


def run() -> dict:
    rows = []
    # --- CNNs: MAC-unit overhead of the exit path ---
    for mk in (b_lenet, triple_wins_lenet, b_alexnet):
        cfg = mk()
        w_exit = sum(pm.cnn_exit_workloads(cfg, 0))
        w_bb = sum(pm.cnn_stage_workloads(cfg, 0)) + \
            sum(pm.cnn_stage_workloads(cfg, 1))
        # buffer bytes: stage-1 output feature map held while deciding
        h, w, c = pm._stage_out_shape(cfg, 1)
        buf = h * w * c * 4
        rows.append([cfg.name, f"{w_exit:,.0f}",
                     f"{100 * w_exit / (w_exit + w_bb):.1f}%",
                     f"{buf / 1024:.0f} KiB"])

    # --- LM archs: exit head FLOPs (norm + unembed) vs one fwd pass ---
    for a in LM_ARCHS:
        cfg = get_arch(a)
        spec = ee.default_spec(cfg)
        seq = 4096
        f_exit = 2.0 * cfg.d_model * cfg.vocab          # per decided token
        f_bb = pm.stage_flops_per_sample(cfg, 0, cfg.n_layers,
                                         kind="prefill", seq_len=seq) / seq
        buf = seq * cfg.d_model * 2                     # slab row, bf16
        rows.append([a, f"{f_exit:,.0f}",
                     f"{100 * f_exit / (f_exit + f_bb):.2f}%",
                     f"{buf / 1024:.0f} KiB/sample"])
    txt = table(
        "Table II — EE overhead (exit path vs backbone; buffer = "
        "conditional-buffer footprint)",
        ["network", "exit-path work", "share of total", "buffer"], rows)
    return {"text": txt}


def main() -> None:
    print(run()["text"])


if __name__ == "__main__":
    main()
