"""Fused dispatch microbenchmark — one-program decision+compaction+enqueue
vs the composed chain it replaced (exit_decision, per-leaf gather_compact,
ranged ring enqueue: 4+ separate device programs and an intermediate slab
materialization). Sized to be launch-overhead/bandwidth dominated — the
regime the steady-state decode tick lives in — so the ratio tracks the
dispatch-fusion win, not model FLOPs. Parity is asserted (bitwise ring
state) before anything is timed; the ratio and the parity verdict ride the
``--json`` envelope and are gated against ``baseline_cpu.json`` with a
hard ``min: 1.0`` (fused must never be slower than composed)."""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import table
from repro.kernels import dispatch
from repro.runtime import scheduler as SCH

_B, _V, _D = 64, 2048, 128


def _mk_inputs(key):
    k1, k2, k3 = jax.random.split(key, 3)
    logits = jax.random.normal(k1, (_B, _V), jnp.float32) * 2.0
    payload = {"h": jax.random.normal(k2, (_B, _D), jnp.float32),
               "step": jax.random.randint(k3, (_B,), 0, 1024, jnp.int32)}
    sample_ids = jnp.arange(_B, dtype=jnp.int32)
    spec = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), payload)
    return logits, sample_ids, payload, spec


def _composed_step(logits, sample_ids, payload, ring, c_thr, backend):
    """The pre-fusion chain, one device program per stage (what the
    composed tick still runs under disaggregated placements)."""
    exit_mask, pred, conf = dispatch.exit_decision_op(logits, c_thr,
                                                      backend=backend)
    hard = ~exit_mask
    slab = jax.tree.map(
        lambda x: dispatch.gather_compact_op(x, hard, _B,
                                             backend=backend)[0], payload)
    _, src, n_hard = dispatch.gather_compact_op(
        jnp.zeros((_B, 1), jnp.float32), hard, _B, backend=backend)
    slab_ids = jnp.where(src >= 0,
                         jnp.take(sample_ids, jnp.maximum(src, 0)), -1)
    ring = SCH._ring_enqueue_range(ring, slab, slab_ids, 0, _B)
    return ring, exit_mask, pred, conf, src, n_hard


def _check_parity(key, backend) -> bool:
    logits, sample_ids, payload, spec = _mk_inputs(key)
    ring_f = SCH.ring_init(64, spec)
    ring_c = jax.tree.map(jnp.copy, ring_f)
    got = dispatch.fused_dispatch_op(logits, None, sample_ids, payload,
                                     ring_f, 0.55, backend=backend,
                                     donate=False)
    want = _composed_step(logits, sample_ids, payload, ring_c, 0.55, backend)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        if not np.array_equal(np.asarray(g), np.asarray(w)):
            return False
    return True


def _time_loop(step, iters: int, repeats: int) -> float:
    """Best-of-repeats wall time for ``iters`` chained steps (the ring
    threads through, so every step really executes)."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = None
        for _ in range(iters):
            out = step()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def run(fast: bool = False) -> dict:
    backend = dispatch.kernel_backend()
    key = jax.random.PRNGKey(0)
    parity = _check_parity(key, backend)

    logits, sample_ids, payload, spec = _mk_inputs(key)
    iters, repeats = (30, 3) if fast else (100, 5)
    # a ring big enough that the timed loop never fills it: every step
    # writes its full hard set, exactly the steady-state enqueue
    size = max(256, iters * _B + _B)
    c_thr = 0.55                      # mixed traffic, q ~ 0.2-0.4

    state_f = {"ring": SCH.ring_init(size, spec)}
    state_c = {"ring": jax.tree.map(jnp.copy, state_f["ring"])}

    def fused_step():
        (state_f["ring"], e, p, c, s, n) = dispatch.fused_dispatch_op(
            logits, None, sample_ids, payload, state_f["ring"], c_thr,
            donate=True)
        return n

    def composed_step():
        (state_c["ring"], e, p, c, s, n) = _composed_step(
            logits, sample_ids, payload, state_c["ring"], c_thr, backend)
        return n

    fused_step()                       # warm both compile caches
    composed_step()
    jax.block_until_ready((state_f["ring"], state_c["ring"]))
    state_f["ring"] = SCH.ring_init(size, spec)
    state_c["ring"] = jax.tree.map(jnp.copy, state_f["ring"])

    t_fused = _time_loop(fused_step, iters, repeats)
    t_composed = _time_loop(composed_step, iters, repeats)
    ratio = t_composed / t_fused if t_fused > 0 else float("inf")

    us = 1e6 / iters
    txt = table(
        "Kernel dispatch — fused one-pass vs composed chain "
        f"(B={_B}, V={_V}, d={_D}, backend={backend})",
        ["variant", "programs/step", "us/step", "speedup"],
        [["composed (decision+compact+enqueue)", "5",
          f"{t_composed * us:.1f}", "1.00x"],
         ["fused (one program)", "1", f"{t_fused * us:.1f}",
          f"{ratio:.2f}x"],
         ["parity (bitwise ring state)", "-", "-",
          "PASS" if parity else "FAIL"]])
    if not parity:
        raise AssertionError("fused dispatch diverged from the composed "
                             "chain — not benchmarking a wrong kernel")
    return {"text": txt, "parity": parity,
            "fused_vs_composed": round(ratio, 3),
            "fused_us_per_step": round(t_fused * us, 2),
            "composed_us_per_step": round(t_composed * us, 2)}


def main() -> None:
    print(run()["text"])


if __name__ == "__main__":
    main()
