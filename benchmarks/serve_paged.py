"""Paged vs dense KV cache under an EQUAL cache-HBM budget.

The dense continuous scheduler reserves a full ``max_len`` cache row per
slot, so the HBM budget fixes the slot count at ``budget / (max_len *
bytes_per_token)`` — most of which sits unwritten when generation lengths
are long-tailed (the realistic serving regime: many short answers, a rare
long one that sets ``max_len``). The paged scheduler spends the SAME bytes
on a shared page pool and allocates each slot only ``ceil(span / page)``
pages, so short requests stop paying for the long tail's reservation and
the pool admits several times more concurrent slots.

Per q in {0.1, 0.3, 0.5} (C_thr calibrated exactly like
``serve_continuous``), on one request trace:

  * token-stream equivalence is enforced BEFORE timing: paged streams must
    equal dense streams AND the ``HostLoopDecoder`` oracle per sample id
    (the paged decode path is *bitwise* dense — gathering a block table
    over the zero NULL page reconstructs the dense cache row exactly);
  * the paged pool's ``cache_hbm_bytes`` is asserted within 5% of the
    dense pool's (the +1 NULL page is the only overhead) — the "equal
    budget" premise is measured, not assumed;
  * ``slots_ratio`` = peak concurrently-live paged slots / dense slot
    count at the shared budget (gated: target 3x, hard floor 2x);
  * ``goodput_ratio`` = paged / dense tokens-per-second of scheduler-clock
    makespan, median over paired passes (hard floor 1.0x at q = 0.3: the
    paged indirection must never lose end-to-end at equal HBM);
  * ``ring_bytes_ratio`` = dense / paged ``ring_bytes_moved`` at q = 0.3
    (hard floor 5x: the paged ring hops page INDICES, not cache rows).

Run via ``PYTHONPATH=src python -m benchmarks.run --only serve_paged
[--json]``.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import table
from repro.core import early_exit as ee
from repro.models.config import ArchConfig
from repro.runtime import serve_loop as SL
from repro.runtime.scheduler import (ContinuousScheduler, Request,
                                     poisson_arrivals)

Q_GRID = (0.1, 0.3, 0.5)
ARRIVAL_RATE = 2000.0
PAGE = 4
SEQ = 8


def _bench_cfg() -> ArchConfig:
    return ArchConfig(
        name="serve-paged-bench", family="dense", n_layers=4, d_model=32,
        n_heads=4, n_kv_heads=2, d_ff=64, vocab=256,
        dtype="float32", param_dtype="float32", tie_embeddings=True,
    )


class _PeakLive:
    """Tick controller recording peak busy slots and peak page
    fragmentation (the post-drain stats read zeros — every page is home)."""

    def __init__(self):
        self.peak = 0
        self.frag = 0.0

    def on_tick(self, sched, n_dec, n_hard, conf):
        self.peak = max(self.peak, sched.n_slots - len(sched._free))
        self.frag = max(self.frag, sched.stats.page_fragmentation)


def _make_requests(prompts, n_tokens, seed: int) -> List[Request]:
    arrivals = poisson_arrivals(len(prompts), ARRIVAL_RATE, seed)
    return [Request(sample_id=i, prompt=prompts[i],
                    n_tokens=int(n_tokens[i]),
                    arrival_time=float(arrivals[i]))
            for i in range(len(prompts))]


def _one_pass(make_sched, reqs):
    sched = make_sched()
    peak = _PeakLive()
    sched.controller = peak
    for r in reqs:
        sched.submit(r)
    results = sched.run()
    makespan = sched.clock.now()
    tps = sum(len(v) for v in results.values()) / makespan
    return results, tps, sched.stats, peak


def run(fast: bool = False) -> dict:
    # long-tailed generation lengths: the rare long request sets max_len
    # (and thereby the dense per-slot reservation); the short majority is
    # what the paged pool reclaims
    tok_choices, tok_p = (2, 4, 6, 40), (0.42, 0.3, 0.2, 0.08)
    max_len = SEQ + max(tok_choices)                      # 48, page-aligned
    assert max_len % PAGE == 0
    n_requests, iters = (64, 6) if fast else (96, 4)
    n_slots_dense = 4                                     # sets the budget
    n_pages = n_slots_dense * (max_len // PAGE)           # equal HBM budget
    n_slots_paged = 12                                    # bt rows are cheap

    cfg = _bench_cfg()
    spec0 = ee.EarlyExitSpec(exit_layer=2, c_thr=0.5)
    params = ee.init_ee_params(jax.random.PRNGKey(0), cfg, spec0)
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (n_requests, SEQ), 0, cfg.vocab))
    n_tokens = np.random.default_rng(7).choice(tok_choices, size=n_requests,
                                               p=tok_p)
    conf = SL.decode_step0_confidences(params, cfg, spec0, prompts[:8],
                                       max_len=max_len)
    dense_fns = SL.decode_stage_fns(params, cfg, spec0)
    paged_fns = SL.decode_stage_fns(params, cfg, spec0, page_size=PAGE)

    rows, data = [], {}
    for q in Q_GRID:
        c_thr = float(jnp.quantile(conf, q))
        sc_d = SL.ServeConfig(capacity=max(2, int(np.ceil(q * n_slots_dense))),
                              queue_depth=4, c_thr=c_thr)
        sc_p = SL.ServeConfig(capacity=max(2, int(np.ceil(q * n_slots_paged))),
                              queue_depth=4, c_thr=c_thr)
        mk_dense = lambda: ContinuousScheduler(
            dense_fns, sc_d, n_slots=n_slots_dense, max_len=max_len)
        mk_paged = lambda: ContinuousScheduler(
            paged_fns, sc_p, n_slots=n_slots_paged, max_len=max_len,
            n_pages=n_pages)
        reqs = _make_requests(prompts, n_tokens, seed=11)

        # --- correctness + budget gates BEFORE timing
        oracle = SL.HostLoopDecoder(dense_fns, sc_d).generate(
            prompts, max(tok_choices))
        want = {i: [int(x) for x in oracle["tokens"][i][:int(n_tokens[i])]]
                for i in range(n_requests)}
        res_d, _, st_d, pk_d = _one_pass(mk_dense, reqs)
        res_p, _, st_p, pk_p = _one_pass(mk_paged, reqs)
        peak_d, peak_p = pk_d.peak, pk_p.peak
        equiv = (res_d == want) and (res_p == want)
        assert equiv, f"paged token-stream equivalence broke at q={q}"
        assert st_p.cache_hbm_bytes <= 1.05 * st_d.cache_hbm_bytes, (
            f"paged pool exceeds the dense HBM budget at q={q}: "
            f"{st_p.cache_hbm_bytes} vs {st_d.cache_hbm_bytes}")
        slots_ratio = peak_p / n_slots_dense
        ring_ratio = st_d.ring_bytes_moved / max(st_p.ring_bytes_moved, 1)

        # --- timed paired passes; median of per-pair ratios (same
        # rationale as serve_continuous: drift hits both sides of a pair)
        _one_pass(mk_dense, reqs)
        _one_pass(mk_paged, reqs)
        ratios, best_d, best_p = [], 0.0, 0.0
        for _ in range(iters):
            _, tps_d, _, _ = _one_pass(mk_dense, reqs)
            _, tps_p, _, _ = _one_pass(mk_paged, reqs)
            best_d, best_p = max(best_d, tps_d), max(best_p, tps_p)
            ratios.append(tps_p / tps_d)
        goodput_ratio = float(np.median(ratios))

        rows.append([f"{q:.1f}", f"{st_p.realized_q:.2f}",
                     f"{peak_d}/{n_slots_dense}",
                     f"{peak_p}/{n_slots_paged}", f"{slots_ratio:.1f}x",
                     f"{best_d:,.0f}", f"{best_p:,.0f}",
                     f"{goodput_ratio:.2f}x", f"{ring_ratio:.0f}x",
                     f"{pk_p.frag:.2f}", equiv])
        data[f"q{q}"] = {
            "equivalence": bool(equiv), "goodput_ratio": goodput_ratio,
            "slots_ratio": slots_ratio, "ring_bytes_ratio": ring_ratio,
            "dense_goodput": best_d, "paged_goodput": best_p,
            "dense_ring_bytes": st_d.ring_bytes_moved,
            "paged_ring_bytes": st_p.ring_bytes_moved,
            "paged_hbm_bytes": st_p.cache_hbm_bytes,
            "dense_hbm_bytes": st_d.cache_hbm_bytes,
            "page_fragmentation": pk_p.frag,
        }

    # the gated scalars (q=0.3 carries the contract)
    data["slots_ratio"] = data["q0.3"]["slots_ratio"]
    data["goodput_ratio"] = data["q0.3"]["goodput_ratio"]
    data["ring_bytes_ratio"] = data["q0.3"]["ring_bytes_ratio"]
    data["equivalence"] = all(data[f"q{q}"]["equivalence"] for q in Q_GRID)
    txt = table(
        "Paged vs dense KV cache at equal HBM "
        f"(N={n_requests}, prompt={SEQ}, T∈{tok_choices}, page={PAGE}, "
        f"pool={n_pages}p, dense={n_slots_dense} slots, "
        f"backend={jax.default_backend()})",
        ["q", "realized q", "dense live", "paged live", "slots",
         "dense tok/s", "paged tok/s", "goodput", "ring bytes",
         "frag", "streams =="], rows)
    return {"text": txt, **data}


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    a = ap.parse_args()
    print(run(fast=a.fast)["text"])
