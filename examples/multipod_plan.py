"""Multi-pod deployment planning: run the ATHEENA LM optimizer for an
assigned architecture, print the two-stage chip apportionment, hand the
CombinedDesign straight to the stage-disaggregated executor path
(StagePlacement.from_design -> disjoint submeshes, when enough devices are
visible), and show the elastic-degradation replan (a pod loses 16 chips).

    PYTHONPATH=src python examples/multipod_plan.py --arch qwen2-7b
"""
import argparse

import jax

from repro.core import dse
from repro.core.stage_mesh import StageMeshPlan
from repro.models.registry import get_arch, list_archs
from repro.runtime.elastic import replan
from repro.runtime.stage_executor import StagePlacement

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen2-7b", choices=list_archs())
ap.add_argument("--p", type=float, default=0.25)
ap.add_argument("--chips", type=int, default=256)
ap.add_argument("--seq", type=int, default=4096)
ap.add_argument("--batch", type=int, default=256)
args = ap.parse_args()

cfg = get_arch(args.arch)
k = cfg.default_exit_layers()[0]
print(f"{args.arch}: exit after layer {k}/{cfg.n_layers}, p={args.p}, "
      f"budget {args.chips} chips")

design = dse.atheena_optimize_lm(cfg, k, args.p, kind="prefill",
                                 seq_len=args.seq, batch=args.batch,
                                 chips=args.chips)
d = design.combined
plan = StageMeshPlan.from_design(d)
print(f"stage 1: {plan.chips1} chips (dp={plan.plan1.dp} tp={plan.plan1.tp} "
      f"fsdp={plan.plan1.fsdp}) -> {d.stage1.throughput:,.0f} samples/s")
print(f"stage 2: {plan.chips2} chips (dp={plan.plan2.dp} tp={plan.plan2.tp} "
      f"fsdp={plan.plan2.fsdp}) -> {d.stage2.throughput:,.0f} samples/s "
      f"(effective x1/p: {d.stage2.throughput / args.p:,.0f})")
print(f"combined: {d.design_throughput:,.0f} samples/s = "
      f"{design.gain_vs_baseline():.2f}x baseline at the same budget")
print(f"robustness band: q=p-5% {d.throughput_at(args.p - 0.05):,.0f} | "
      f"q=p {d.throughput_at(args.p):,.0f} | "
      f"q=p+5% {d.throughput_at(args.p + 0.05):,.0f}")

# --- the design goes straight into the serving runtime -----------------------
# StagePlacement.from_design carves disjoint (data, model) submeshes per the
# plan above; runtime.serve_api.build(..., placement=...) then runs
# stage 1 and stage 2 on them with per-stage resident params.
n_dev = jax.device_count()
if n_dev >= plan.chips1 + plan.chips2:
    placement = StagePlacement.from_design(d)
    print(f"\nexecutor path: {placement}")
else:
    print(f"\nexecutor path: needs {plan.chips1 + plan.chips2} devices, "
          f"{n_dev} visible — on a CPU host export "
          f"XLA_FLAGS=--xla_force_host_platform_device_count="
          f"{plan.chips1 + plan.chips2} (or pass the plan to "
          f"`python -m repro.launch.serve --disaggregate "
          f"--chips1 {plan.chips1} --chips2 {plan.chips2}`)")

# --- elastic: lose 16 chips, replan from the same TAPs -----------------------
ep = replan(design.tap1, design.tap2, args.p, chips_before=args.chips,
            chips_after=args.chips - 16)
if ep:
    d2 = ep.design
    print(f"\nafter losing 16 chips: stage1 {d2.stage1.resources[0]:.0f} + "
          f"stage2 {d2.stage2.resources[0]:.0f} chips -> "
          f"{ep.throughput_after:,.0f} samples/s "
          f"({100 * ep.degradation:.1f}% of the healthy-mesh throughput)")
