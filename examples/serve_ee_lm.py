"""End-to-end driver: serve a small LM with batched requests through the
two-stage Early-Exit pipeline (the paper's deployment scenario), prefill
AND autoregressive decode.

    PYTHONPATH=src python examples/serve_ee_lm.py [--requests 512]

Flow: init a reduced qwen2-family model -> calibrate C_thr on a profiling
batch so p_hard ~ 0.25 -> size the stage-2 bucket from p (+slack) -> serve
batched requests through the device-resident TwoStageServer (fused exit
decision + compaction via the kernel dispatch layer, device ring buffer,
async bucket drains) -> report throughput, realized q, bucket occupancy,
and verify every request got an answer consistent with the one-shot
pipeline. Then the same model generates continuations through the
decode-time DecodeServer (per-token exit decisions; hard tokens' hidden
rows + stage-2 KV-cache segment rows through the pytree ring) and the
output is verified bitwise against the host-loop decode baseline."""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import early_exit as ee
from repro.core import exit_decision as ed
from repro.core.stage_mesh import stage2_capacity
from repro.models.registry import get_smoke
from repro.runtime import serve_loop as SL

ap = argparse.ArgumentParser()
ap.add_argument("--requests", type=int, default=512)
ap.add_argument("--batch", type=int, default=32)
ap.add_argument("--seq", type=int, default=48)
ap.add_argument("--target-p", type=float, default=0.25)
ap.add_argument("--decode-tokens", type=int, default=16)
args = ap.parse_args()

cfg = get_smoke("qwen2-1.5b")
spec0 = ee.default_spec(cfg)
params = ee.init_ee_params(jax.random.PRNGKey(0), cfg, spec0)

# --- calibrate C_thr on a profiling batch (paper §III-B.1) -------------------
prof_toks = jax.random.randint(jax.random.PRNGKey(1), (256, args.seq), 0,
                               cfg.vocab)
_, _, exit_logits, _ = ee.stage1_prefill(params, cfg, spec0, prof_toks)
c_thr = ed.calibrate_threshold(ed.softmax_confidence(exit_logits),
                               target_exit_rate=1.0 - args.target_p)
spec = ee.EarlyExitSpec(exit_layer=spec0.exit_layer, c_thr=c_thr)
print(f"calibrated C_thr={c_thr:.4f} for target p={args.target_p}")

# --- size stage 2 and build the server --------------------------------------
cap = stage2_capacity(args.batch, args.target_p)
server = SL.build(params, cfg, spec,
                  SL.ServeConfig(capacity=cap, c_thr=c_thr),
                  mode="prefill", scheduler=None)
print(f"stage-2 bucket capacity {cap} (batch {args.batch})")

# --- batched serving ---------------------------------------------------------
toks = np.asarray(jax.random.randint(jax.random.PRNGKey(2),
                                     (args.requests, args.seq), 0, cfg.vocab))
t0 = time.perf_counter()
results = SL.serve_dataset(server, toks, batch=args.batch)
dt = time.perf_counter() - t0
assert len(results) == args.requests, "dropped requests!"

s = server.stats
print(f"served {args.requests} requests in {dt:.2f}s "
      f"({args.requests / dt:,.0f} samples/s on this host)")
print(f"realized q={s.realized_q:.3f}  exited early: {s.n_exited}  "
      f"stage-2: {s.n_stage2}  stalls: {s.n_stalls}  "
      f"mean bucket fill {s.mean_bucket_fill:.2f}")

# --- consistency vs the one-shot fused pipeline ------------------------------
one = ee.serve_batch(params, cfg, spec, jnp.asarray(toks[:args.batch]),
                     capacity=args.batch)
merged = np.asarray(one["logits"])
worst = max(float(np.abs(results[i] - merged[i]).max())
            for i in range(args.batch))
print(f"server vs one-shot pipeline max |delta| over first batch: "
      f"{worst:.2e}")
assert worst < 5e-4

# --- prefill -> decode: per-token EE generation ------------------------------
# The decode threshold is calibrated on the first decode step's exit-head
# confidences (per-token confidence statistics differ from prefill's).
prompts = toks[:args.batch]
dec_conf = SL.decode_step0_confidences(params, cfg, spec, prompts,
                                       max_len=args.seq + 2)
c_thr_dec = ed.calibrate_threshold(dec_conf,
                                   target_exit_rate=1.0 - args.target_p)
spec_dec = ee.EarlyExitSpec(exit_layer=spec0.exit_layer, c_thr=c_thr_dec)
sc_dec = SL.ServeConfig(capacity=cap, c_thr=c_thr_dec)
fns = SL.decode_stage_fns(params, cfg, spec_dec)

dec = SL.DecodeServer(fns, sc_dec)
t0 = time.perf_counter()
gen = dec.generate(prompts, args.decode_tokens)
dt = time.perf_counter() - t0
n_decode = args.batch * (args.decode_tokens - 1)
s = dec.stats
print(f"decoded {args.decode_tokens} tokens x {args.batch} prompts in "
      f"{dt:.2f}s ({n_decode / dt:,.0f} decode tok/s on this host)")
print(f"decode realized q={s.realized_q:.3f} (per token)  "
      f"token exits: {s.n_exited}  stage-2 tokens: {s.n_stage2}  "
      f"stalls: {s.n_stalls}  mean bucket fill {s.mean_bucket_fill:.2f}")

# bitwise parity against the host-loop decode baseline
ref = SL.HostLoopDecoder(fns, sc_dec).generate(prompts, args.decode_tokens)
assert np.array_equal(gen["tokens"], ref["tokens"]), "decode token drift!"
assert np.array_equal(gen["logits"], ref["logits"]), "decode logits drift!"
print("decode output bitwise-identical to the host-loop baseline")
print("OK")
