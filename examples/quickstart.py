"""Quickstart: the whole ATHEENA toolflow in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Train the paper's B-LeNet (joint BranchyNet loss) on synthetic MNIST.
2. Profile the early-exit probability p at a calibrated threshold.
3. Run the ATHEENA optimizer: per-stage TAP curves + the Eq. (1) ⊕ merge.
4. Report the combined design and its gain over the no-exit baseline.
"""
import jax
import jax.numpy as jnp

from repro.core import dse, exit_decision as ed, losses, profiler
from repro.data.pipeline import mnist_like
from repro.models import cnn as C

# 1. train ------------------------------------------------------------------
cfg = C.b_lenet()
data = mnist_like(2048, seed=0, hard_frac=0.3)
params = C.init_cnn(jax.random.PRNGKey(0), cfg)


@jax.jit
def step(p, x, y):
    def loss_fn(p):
        return losses.cnn_joint_loss(C.forward_all_exits(p, cfg, x), y,
                                     (0.3, 1.0))[0]
    return jax.tree.map(lambda a, g: a - 0.05 * g, p, jax.grad(loss_fn)(p))


x, y = jnp.asarray(data["x"]), jnp.asarray(data["y"])
for i in range(150):
    lo = (i * 128) % 1920
    params = step(params, x[lo:lo + 128], y[lo:lo + 128])

# 2. profile ------------------------------------------------------------------
outs = C.forward_all_exits(params, cfg, x)
c_thr = ed.calibrate_threshold(ed.softmax_confidence(outs[0]),
                               target_exit_rate=0.75)
prof = profiler.profile_early_exit(outs[0], outs[-1], y, c_thr)
print(f"profiled: p_hard={prof.p_hard:.2f}  EE acc={prof.cumulative_accuracy:.3f}"
      f"  baseline acc={prof.baseline_accuracy:.3f}  (C_thr={c_thr:.3f})")

# 3. + 4. optimize & report ----------------------------------------------------
design = dse.atheena_optimize_cnn(cfg, p=prof.p_hard, budget=256, n_seeds=3)
d = design.combined
print(f"stage 1: {d.stage1.resources[0]:.0f} MAC units -> "
      f"{d.stage1.throughput:,.0f} samples/s")
print(f"stage 2: {d.stage2.resources[0]:.0f} MAC units -> "
      f"{d.stage2.throughput:,.0f} samples/s (x1/p = "
      f"{d.stage2.throughput / design.p:,.0f} effective)")
print(f"combined design throughput {d.design_throughput:,.0f} samples/s = "
      f"{design.gain_vs_baseline():.2f}x the no-exit baseline")
print(f"robustness: q=20% -> {d.throughput_at(0.20):,.0f}, q=30% -> "
      f"{d.throughput_at(0.30):,.0f} samples/s")
